"""Chunked flash attention + Mamba2 SSD numerical equivalence tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.nn.attention import (KVCache, chunked_attention, decode_attention,
                                update_cache)
from repro.nn.mamba2 import SSMConfig, ssd_chunked


def naive_attention(q, k, v, causal=True, scale=None):
    b, s_q, h, d = q.shape
    kvh = k.shape[2]
    g = h // kvh
    scale = scale or d**-0.5
    qf = q.astype(jnp.float32).reshape(b, s_q, kvh, g, d)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qf, k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((s_q, k.shape[1]), bool))
        logits = jnp.where(mask, logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bkgqd", p, v.astype(jnp.float32))
    return o.transpose(0, 3, 1, 2, 4).reshape(b, s_q, h, d)


@pytest.mark.parametrize("h,kvh", [(4, 4), (8, 2), (6, 2)])
@pytest.mark.parametrize("qc,kc", [(16, 16), (32, 8), (64, 64), (13, 29)])
@pytest.mark.parametrize("causal", [True, False])
def test_chunked_matches_naive(h, kvh, qc, kc, causal, rng):
    b, s, d = 2, 64, 16
    q = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, kvh, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, kvh, d)), jnp.float32)
    got = chunked_attention(q, k, v, causal=causal, q_chunk=qc, kv_chunk=kc)
    want = naive_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_decode_matches_last_row_of_full(rng):
    b, s, h, kvh, d = 2, 33, 4, 2, 16
    q = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, kvh, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, kvh, d)), jnp.float32)
    full = naive_attention(q, k, v, causal=True)
    cache = KVCache(k=k, v=v, length=jnp.full((b,), s, jnp.int32))
    dec = decode_attention(q[:, -1:], cache)
    np.testing.assert_allclose(np.asarray(dec[:, 0]), np.asarray(full[:, -1]),
                               rtol=2e-5, atol=2e-5)


def test_update_cache_appends_at_length(rng):
    b, S, kvh, d = 2, 16, 2, 8
    cache = KVCache(k=jnp.zeros((b, S, kvh, d)), v=jnp.zeros((b, S, kvh, d)),
                    length=jnp.array([3, 7], jnp.int32))
    k_new = jnp.asarray(rng.normal(size=(b, 1, kvh, d)), jnp.float32)
    out = update_cache(cache, k_new, k_new)
    np.testing.assert_allclose(np.asarray(out.k[0, 3]), np.asarray(k_new[0, 0]))
    np.testing.assert_allclose(np.asarray(out.k[1, 7]), np.asarray(k_new[1, 0]))
    assert np.all(np.asarray(out.length) == np.array([4, 8]))


# ---------------------------------------------------------------------------
# Mamba2 SSD
# ---------------------------------------------------------------------------

def ssd_sequential(xdt, dA, B, C):
    """Token-by-token recurrence: h' = h*exp(dA) + xdt (x) B; y = C . h."""
    b, l, h, p = xdt.shape
    g, n = B.shape[2], B.shape[3]
    hg = h // g
    Bh = np.repeat(np.asarray(B), hg, axis=2)
    Ch = np.repeat(np.asarray(C), hg, axis=2)
    state = np.zeros((b, h, p, n))
    ys = np.zeros((b, l, h, p))
    for t in range(l):
        state = state * np.exp(np.asarray(dA)[:, t])[:, :, None, None] + \
            np.asarray(xdt)[:, t][:, :, :, None] * Bh[:, t][:, :, None, :]
        ys[:, t] = np.einsum("bhpn,bhn->bhp", state, Ch[:, t])
    return ys, state


@pytest.mark.parametrize("chunk", [4, 8, 16, 64])
@pytest.mark.parametrize("g", [1, 2])
def test_ssd_chunked_matches_sequential(chunk, g, rng):
    b, l, h, p, n = 2, 64, 4, 8, 16
    xdt = jnp.asarray(rng.normal(size=(b, l, h, p)), jnp.float32)
    dA = jnp.asarray(-np.abs(rng.normal(size=(b, l, h))), jnp.float32) * 0.1
    B = jnp.asarray(rng.normal(size=(b, l, g, n)), jnp.float32)
    C = jnp.asarray(rng.normal(size=(b, l, g, n)), jnp.float32)
    y, state = ssd_chunked(xdt, dA, B, C, chunk=chunk)
    y_ref, state_ref = ssd_sequential(xdt, dA, B, C)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(state), state_ref, rtol=2e-4, atol=2e-4)


def test_ssd_initial_state_continuation(rng):
    """Splitting a sequence across two calls with carried state == one call."""
    b, l, h, p, n, g = 1, 32, 2, 4, 8, 1
    xdt = jnp.asarray(rng.normal(size=(b, l, h, p)), jnp.float32)
    dA = jnp.asarray(-np.abs(rng.normal(size=(b, l, h))), jnp.float32) * 0.1
    B = jnp.asarray(rng.normal(size=(b, l, g, n)), jnp.float32)
    C = jnp.asarray(rng.normal(size=(b, l, g, n)), jnp.float32)
    y_full, s_full = ssd_chunked(xdt, dA, B, C, chunk=8)
    y1, s1 = ssd_chunked(xdt[:, :16], dA[:, :16], B[:, :16], C[:, :16], chunk=8)
    y2, s2 = ssd_chunked(xdt[:, 16:], dA[:, 16:], B[:, 16:], C[:, 16:],
                         init_state=s1, chunk=8)
    np.testing.assert_allclose(np.asarray(y_full[:, 16:]), np.asarray(y2),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s_full), np.asarray(s2),
                               rtol=2e-4, atol=2e-4)
