"""Pallas flash-attention kernel sweep vs the naive oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from test_attention import naive_attention  # pytest puts tests/ on sys.path


@pytest.mark.parametrize("h,kvh", [(4, 4), (8, 2), (6, 3)])
@pytest.mark.parametrize("blocks", [(64, 64), (32, 128), (128, 32)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_matches_naive(h, kvh, blocks, causal, rng):
    b, s, d = 2, 256, 32
    q = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, kvh, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, kvh, d)), jnp.float32)
    got = flash_attention(q, k, v, causal=causal, blocks=blocks, interpret=True)
    want = naive_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


def test_flash_block_invariance(rng):
    b, s, h, d = 1, 256, 4, 64
    q = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    a = flash_attention(q, k, v, blocks=(256, 256), interpret=True)
    c = flash_attention(q, k, v, blocks=(64, 128), interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(c), rtol=3e-5, atol=3e-5)


def test_flash_bf16_io(rng):
    b, s, h, d = 1, 128, 2, 32
    q = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.bfloat16)
    got = flash_attention(q, k, v, blocks=(64, 64), interpret=True)
    want = naive_attention(q.astype(jnp.float32), k.astype(jnp.float32),
                           v.astype(jnp.float32))
    assert got.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want),
                               rtol=2e-2, atol=2e-2)
