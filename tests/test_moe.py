"""MoE dispatch correctness: scatter/gather vs. dense loop-over-experts."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.nn.common import ParamBuilder
from repro.nn.moe import MoEConfig, apply_moe, init_moe


def dense_moe_reference(params, x, cfg, act):
    """Loop over experts with full routing, no capacity limit."""
    b, s, d = x.shape
    xt = x.reshape(-1, d)
    logits = xt.astype(jnp.float32) @ params["router"].astype(jnp.float32)
    scores = (jax.nn.sigmoid(logits) if cfg.gate == "sigmoid"
              else jax.nn.softmax(logits, axis=-1))
    topw, topi = jax.lax.top_k(scores, cfg.top_k)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)
    out = jnp.zeros_like(xt)
    for e in range(cfg.num_experts):
        h = act(xt @ params["w_gate"][e]) * (xt @ params["w_up"][e])
        ye = h @ params["w_down"][e]
        for kk in range(cfg.top_k):
            w = jnp.where(topi[:, kk] == e, topw[:, kk], 0.0)
            out = out + ye * w[:, None].astype(ye.dtype)
    if cfg.num_shared:
        hs = act(xt @ params["ws_gate"]) * (xt @ params["ws_up"])
        out = out + hs @ params["ws_down"]
    return out.reshape(b, s, d)


@pytest.mark.parametrize("top_k,gate,shared", [(1, "softmax", 0),
                                               (2, "softmax", 0),
                                               (2, "sigmoid", 1)])
def test_capacity_dispatch_matches_dense(top_k, gate, shared, rng):
    cfg = MoEConfig(num_experts=4, top_k=top_k, d_ff=32, num_shared=shared,
                    gate=gate)
    pb = ParamBuilder(jax.random.PRNGKey(0), jnp.float32)
    init_moe(pb, 16, cfg)
    x = jnp.asarray(rng.normal(size=(2, 8, 16)), jnp.float32)
    # capacity = all tokens -> no drops -> must equal dense reference
    got, aux = apply_moe(pb.params, x, cfg, jax.nn.silu,
                         capacity=2 * 8 * top_k)
    want = dense_moe_reference(pb.params, x, cfg, jax.nn.silu)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-4, atol=3e-4)
    assert float(aux) >= 0


def test_capacity_one_drops_tokens(rng):
    cfg = MoEConfig(num_experts=2, top_k=1, d_ff=16)
    pb = ParamBuilder(jax.random.PRNGKey(1), jnp.float32)
    init_moe(pb, 8, cfg)
    x = jnp.asarray(rng.normal(size=(1, 16, 8)), jnp.float32)
    full, _ = apply_moe(pb.params, x, cfg, jax.nn.silu, capacity=16)
    tight, _ = apply_moe(pb.params, x, cfg, jax.nn.silu, capacity=1)
    # dropped tokens produce zero expert output -> outputs differ
    assert not np.allclose(np.asarray(full), np.asarray(tight))
    # and dropped rows are exactly zero
    norms = np.linalg.norm(np.asarray(tight[0]), axis=-1)
    assert (norms < 1e-6).sum() >= 16 - 2 * 1  # at most capacity*experts kept


def test_aux_loss_balanced_vs_skewed(rng):
    """A router forced onto one expert must pay a higher balance loss."""
    cfg = MoEConfig(num_experts=4, top_k=1, d_ff=16, router_aux_weight=1.0)
    pb = ParamBuilder(jax.random.PRNGKey(2), jnp.float32)
    init_moe(pb, 8, cfg)
    x = jnp.asarray(rng.normal(size=(1, 64, 8)), jnp.float32)
    _, aux_rand = apply_moe(pb.params, x, cfg, jax.nn.silu)
    skew = dict(pb.params)
    skew["router"] = pb.params["router"].at[:, 0].set(100.0)
    _, aux_skew = apply_moe(skew, x, cfg, jax.nn.silu)
    assert float(aux_skew) > float(aux_rand)
