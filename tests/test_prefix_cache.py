"""Radix-tree prefix caching + chunked prefill.

The invariant under test everywhere: prefix reuse only *skips* work. Because
paged prefill always runs on the absolute chunk grid (same chunk programs,
same chunk-table buckets, regardless of how much prefix was cached),
cache-on and cache-off admissions must produce bit-identical token streams
AND bit-identical pool contents — in float and GRAU modes, under eviction
churn, block free-then-reuse, copy-on-write partial-block divergence, and
the CI device-mesh matrix.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.archs import get_config
from repro.launch.mesh import make_serve_mesh
from repro.models import lm
from repro.models.config import GRAUConfig
from repro.nn.common import build_lm_grau
from repro.serve import kv_cache as kvc
from repro.serve.engine import EngineConfig, Request, ServeEngine
from repro.serve.radix_cache import RadixCache
from repro.serve.sampling import SamplingParams

CFG = get_config("llama3.2-3b", smoke=True)


@pytest.fixture(scope="module")
def params():
    p, _ = lm.init_lm(CFG, jax.random.PRNGKey(0), dtype=jnp.float32)
    return p


def shared_prefix_requests(n, *, prefix_len=70, n_prefixes=2, max_new=4,
                           seed=3, sampling=SamplingParams(), tail=(2, 12)):
    rng = np.random.default_rng(seed)
    prefixes = [rng.integers(2, CFG.vocab_size, size=prefix_len)
                for _ in range(n_prefixes)]
    reqs = []
    for i in range(n):
        pre = prefixes[int(rng.integers(0, n_prefixes))]
        t = rng.integers(2, CFG.vocab_size,
                         size=int(rng.integers(tail[0], tail[1])))
        reqs.append(Request(rid=i, prompt=np.concatenate([pre, t]),
                            max_new_tokens=max_new, sampling=sampling))
    return reqs


def ecfg(prefix_cache, **kw):
    base = dict(slots=2, max_seq=128, page_size=8, prefill_chunk=16,
                prefix_cache=prefix_cache)
    base.update(kw)
    return EngineConfig(**base)


# ---------------------------------------------------------------------------
# BlockAllocator: refcounts and the double-free guard (regression)
# ---------------------------------------------------------------------------

def test_allocator_double_free_raises():
    alloc = kvc.BlockAllocator(8)
    a = alloc.alloc(3)
    alloc.free(a)
    with pytest.raises(ValueError, match="double-free"):
        alloc.free([a[0]])
    # the failed free must not have corrupted the free list
    assert alloc.free_blocks == 7
    assert sorted(alloc.alloc(7)) == list(range(1, 8))


def test_allocator_rejects_bogus_ids():
    alloc = kvc.BlockAllocator(8)
    with pytest.raises(ValueError, match="never-allocated"):
        alloc.free([3])                      # never allocated
    with pytest.raises(ValueError, match="null block"):
        alloc.free([kvc.NULL_BLOCK])
    with pytest.raises(ValueError, match="out-of-range"):
        alloc.free([99])
    with pytest.raises(ValueError, match="out-of-range"):
        alloc.free([-1])
    assert alloc.free_blocks == 7            # nothing leaked into the list


def test_allocator_refcounts_share_and_release():
    alloc = kvc.BlockAllocator(8)
    (b,) = alloc.alloc(1)
    alloc.incref([b])                        # second holder
    alloc.free([b])                          # first drop: still live
    assert alloc.refcount(b) == 1
    assert alloc.free_blocks == 6
    alloc.free([b])                          # last drop: recycled
    assert alloc.refcount(b) == 0
    assert alloc.free_blocks == 7
    with pytest.raises(ValueError):
        alloc.free([b])                      # third drop is a double-free
    with pytest.raises(ValueError, match="unallocated"):
        alloc.incref([b])


# ---------------------------------------------------------------------------
# RadixCache unit behaviour
# ---------------------------------------------------------------------------

def _cache(num_blocks=32, bs=4):
    alloc = kvc.BlockAllocator(num_blocks)
    return RadixCache(alloc, bs), alloc


def test_radix_match_is_block_aligned_and_token_exact():
    cache, alloc = _cache()
    toks = np.arange(100, 112)               # 3 full 4-token blocks
    blocks = alloc.alloc(3)
    cache.insert(toks, blocks)
    m = cache.match(toks)
    assert m.tokens_matched == 12 and m.blocks == blocks
    # a mid-block token flip kills that block and everything after it
    bad = toks.copy()
    bad[5] = 9
    m = cache.match(bad)
    assert m.tokens_matched == 4 and m.blocks == blocks[:1]
    assert cache.match(np.array([1, 2, 3])).tokens_matched == 0


def test_radix_partial_block_cow_probe():
    cache, alloc = _cache()
    toks = np.arange(100, 108)               # 2 full blocks
    blocks = alloc.alloc(2)
    cache.insert(toks, blocks)
    # 1 full block + 2 tokens into the second: COW covers the remainder
    m = cache.match(toks[:6])
    assert m.tokens_matched == 4
    assert m.cow_src == blocks[1] and m.cow_tokens == 2
    # diverging inside the partial block: no COW source
    bad = toks[:6].copy()
    bad[5] = 9
    assert cache.match(bad).cow_src is None


def test_radix_insert_shares_and_refcounts():
    cache, alloc = _cache()
    toks = np.arange(50, 58)
    blocks = alloc.alloc(2)
    cache.insert(toks, blocks)
    assert all(alloc.refcount(b) == 2 for b in blocks)   # owner + cache
    # a second identical insert keeps the existing nodes (no double ref,
    # no new nodes)
    dup = alloc.alloc(2)
    before = cache.num_nodes()
    _, walked = cache.insert(toks, dup)
    assert cache.num_nodes() == before and len(walked) == 2
    assert all(alloc.refcount(b) == 2 for b in blocks)
    assert all(alloc.refcount(b) == 1 for b in dup)


def test_radix_incremental_insert_resumes_from_cursor():
    """Chunk-by-chunk publish: extending from the previous deepest node
    builds the same trie as one root walk, and the pinned cursor chain
    survives eviction pressure."""
    cache, alloc = _cache()
    toks = np.arange(200, 212)               # 3 blocks
    blocks = alloc.alloc(3)
    tail, w1 = cache.insert(toks[:4], blocks[:1])
    tail, w2 = cache.insert(toks[4:], blocks[1:], node=tail)
    assert len(w1) + len(w2) == 3
    cache.pin(w1 + w2)
    m = cache.match(toks)
    assert m.tokens_matched == 12 and m.blocks == blocks
    alloc.free(blocks)                       # cache-only now, but pinned
    assert cache.evictable_blocks() == 0
    cache.evict(99)
    assert cache.match(toks).tokens_matched == 12
    cache.unpin(w1 + w2)
    cache.evict(99)
    assert cache.match(toks).tokens_matched == 0


def test_radix_lru_eviction_skips_pinned():
    cache, alloc = _cache(num_blocks=8, bs=4)
    a = alloc.alloc(2)
    cache.insert(np.arange(0, 8), a)
    b = alloc.alloc(2)
    cache.insert(np.arange(100, 108), b)
    alloc.free(a)
    alloc.free(b)                            # both chains now cache-only
    assert alloc.free_blocks == 3
    m = cache.match(np.arange(100, 108))     # pin the fresher chain
    cache.pin(m.nodes)
    assert cache.evictable_blocks() == 2     # only the unpinned chain
    cache.evict(7)                           # ask for more than evictable
    assert alloc.free_blocks == 5            # chain `a` gone, `b` survives
    assert cache.match(np.arange(0, 8)).tokens_matched == 0
    assert cache.match(np.arange(100, 108)).tokens_matched == 8
    cache.unpin(m.nodes)
    cache.evict(7)
    assert alloc.free_blocks == 7
    assert cache.evictions == 4


def test_radix_deep_chain_walks_do_not_recurse():
    """Long-context prompts build trie chains thousands of nodes deep;
    every traversal must be iterative (a recursive walk dies at Python's
    default recursion limit around depth 1000)."""
    alloc = kvc.BlockAllocator(2002)
    cache = RadixCache(alloc, 1)               # 1-token blocks: depth = len
    toks = np.arange(2000) % 7
    blocks = alloc.alloc(2000)
    tail, walked = cache.insert(toks, blocks)
    assert len(walked) == 2000
    alloc.free(blocks)                         # cache-only chain
    assert cache.evictable_blocks() == 2000
    assert cache.num_nodes() == 2000
    assert cache.match(toks).tokens_matched == 2000
    assert cache.evict(2001) == 2000           # leaf-first teardown
    assert alloc.free_blocks == 2001


def test_prefill_chunk_auto_adapts_to_page_size(params):
    """The default chunk must work for any valid page size, not just the
    small ones: page_size=64 engines used to be constructible and must
    stay so without the caller touching prefill_chunk."""
    eng = ServeEngine(CFG, params, EngineConfig(slots=1, max_seq=256,
                                                page_size=64))
    assert eng.prefill_chunk == 64
    eng = ServeEngine(CFG, params, EngineConfig(slots=1, max_seq=256,
                                                page_size=16))
    assert eng.prefill_chunk == 32


def test_chunk_grid_coverage_and_warmup_widths(params):
    """The absolute chunk grid underwrites the bit-exactness story: every
    reachable (ctx, cached) pair must decompose into grid chunks whose
    table widths all sit in the engine's warmed set — so organic traffic
    (hits, misses, partial reuse) can never reach an untraced width."""
    for max_seq, page, chunk in [(64, 8, 16), (128, 8, 32), (256, 16, 32)]:
        eng = ServeEngine(CFG, params, EngineConfig(
            slots=1, max_seq=max_seq, page_size=page, prefill_chunk=chunk))
        widths = set()
        for ctx in range(1, max_seq):
            for cached in range(0, ctx + 1, chunk):
                for p0 in kvc.chunk_starts(cached, ctx, chunk):
                    assert p0 % chunk == 0                  # on the grid
                    widths.add(kvc.chunk_table_width(
                        p0, chunk, page, eng.chunk_buckets))
        assert widths == set(eng.chunk_widths)   # warmup covers exactly these
    with pytest.raises(ValueError, match="grid"):
        kvc.chunk_starts(8, 64, 16)              # off-grid cached prefix


# ---------------------------------------------------------------------------
# Engine: cache-on == cache-off, bit for bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sampling", [
    SamplingParams(),
    SamplingParams(temperature=0.8, top_k=40, top_p=0.9),
], ids=["greedy", "sampled"])
def test_cache_on_off_streams_bit_identical(params, sampling):
    """Randomized shared-prefix workload: enabling the radix cache must not
    change a single token, greedy or sampled."""
    out = {}
    for on in (False, True):
        eng = ServeEngine(CFG, params, ecfg(on, seed=5))
        reqs = shared_prefix_requests(8, sampling=sampling)
        eng.run(reqs)
        out[on] = {r.rid: tuple(r.out_tokens) for r in reqs}
    assert out[True] == out[False]


def _slot_prefix_views(engine, slot, ctx):
    """Dense (reps, ctx, kvh, hd) views of one slot's prompt-context KV,
    gathered bitwise from the pool through the live table."""
    row = engine.block_table[slot, :engine.blocks_per_slot]
    views = []
    for leaf in jax.tree.leaves(engine.caches):
        arr = np.asarray(leaf)                       # (reps, nb, bs, kvh, hd)
        reps, _, bs, kvh, hd = arr.shape
        dense = arr[:, row].reshape(reps, -1, kvh, hd)
        views.append(dense[:, :ctx])
    return views


def test_cache_on_off_pool_contents_bit_identical(params):
    """Freeze both engines right after every admission finished prefilling
    (decode still running) and compare each slot's prompt-context KV region
    gathered from the pool: reused blocks must hold byte-for-byte what the
    cache-off engine recomputed."""
    engines, reqs = {}, {}
    for on in (False, True):
        eng = ServeEngine(CFG, params, ecfg(on))
        rq = shared_prefix_requests(2, max_new=40, seed=11, n_prefixes=1)
        for r in rq:
            eng.submit(r)
        for _ in range(30):
            eng.step()
        eng._drain()
        # every slot must be past prefill and still decoding
        assert all(rs is not None and rs.prefill_pos >= rs.prefill_ctx
                   for rs in eng.slot_req)
        engines[on], reqs[on] = eng, rq
    for slot in range(2):
        rs_on = engines[True].slot_req[slot]
        rs_off = engines[False].slot_req[slot]
        assert rs_on.rid == rs_off.rid           # same FCFS slot assignment
        ctx = rs_on.prefill_ctx
        v_on = _slot_prefix_views(engines[True], slot, ctx)
        v_off = _slot_prefix_views(engines[False], slot, ctx)
        for a, b in zip(v_on, v_off):
            np.testing.assert_array_equal(a, b)
    # the second admission must actually have reused the first one's prefix
    assert engines[True].slot_req[1].cached_prefix_tokens > 0


def test_identical_resubmit_skips_prefill(params):
    """Second identical prompt: the whole chunk-grid-aligned context comes
    from the cache and the token stream matches the first run exactly."""
    eng = ServeEngine(CFG, params, ecfg(True, slots=1))
    p = np.random.default_rng(0).integers(2, CFG.vocab_size, size=66)
    r1 = Request(rid=0, prompt=p, max_new_tokens=4)
    eng.run([r1])
    r2 = Request(rid=1, prompt=p, max_new_tokens=4)
    eng.run([r2])
    rs2 = eng.scheduler.finished[-1]
    # ctx=65 -> 64 grid-aligned tokens cached, one suffix chunk computed
    assert rs2.cached_prefix_tokens == 64
    assert rs2.computed_prefill_tokens == 1
    assert r1.out_tokens == r2.out_tokens


def test_cow_partial_block_divergence(params):
    """A shorter prompt sharing a donor's partial block reuses it
    copy-on-write: zero suffix prefill, bit-exact tokens, and the donor's
    cached block survives the borrower's decode writes."""
    eng = ServeEngine(CFG, params, ecfg(True, slots=1))
    donor = np.random.default_rng(1).integers(2, CFG.vocab_size, size=53)
    r1 = Request(rid=0, prompt=donor, max_new_tokens=4)
    eng.run([r1])
    r2 = Request(rid=1, prompt=donor[:44], max_new_tokens=4)  # ctx=43: 5
    eng.run([r2])                       # full blocks + 3 tokens of block 6
    rs2 = eng.scheduler.finished[-1]
    assert rs2.cached_prefix_tokens == 43
    assert rs2.computed_prefill_tokens == 0
    # cache-off oracle for the borrower
    off = ServeEngine(CFG, params, ecfg(False, slots=1))
    r1b = Request(rid=0, prompt=donor, max_new_tokens=4)
    off.run([r1b])
    r2b = Request(rid=1, prompt=donor[:44], max_new_tokens=4)
    off.run([r2b])
    assert r2.out_tokens == r2b.out_tokens
    # the donor's prefix must be uncorrupted by the borrower's decode
    r3 = Request(rid=2, prompt=donor, max_new_tokens=4)
    eng.run([r3])
    assert r3.out_tokens == r1.out_tokens


def test_eviction_churn_and_free_then_reuse(params):
    """Tiny pool + rotating prefixes: admissions must evict cold prefixes,
    recycle their blocks, and still match the cache-off streams exactly."""
    out, evictions = {}, 0
    for on in (False, True):
        eng = ServeEngine(CFG, params, ecfg(on, max_seq=64, num_blocks=17))
        warm = eng.warmup()
        rng = np.random.default_rng(2)
        prefixes = [rng.integers(2, CFG.vocab_size, size=30)
                    for _ in range(4)]
        reqs = [Request(rid=i,
                        prompt=np.concatenate(
                            [prefixes[i % 4],
                             rng.integers(2, CFG.vocab_size, size=3)]),
                        max_new_tokens=3)
                for i in range(12)]
        done = eng.run(reqs)
        assert len(done) == 12
        assert eng.compile_count() == warm
        out[on] = {r.rid: tuple(r.out_tokens) for r in reqs}
        if on:
            evictions = eng.metrics()["evictions"]
            # nothing leaked: cache refs + free list account for every block
            assert (eng.allocator.free_blocks
                    + eng.allocator.live_blocks) == 16
    assert evictions > 0                  # the pool actually churned
    assert out[True] == out[False]


def test_same_tick_overcommit_requeues_instead_of_crashing(params):
    """policy='prefill' picks every admissible request against the *same*
    free+evictable pool before any admission lands; when the later pick no
    longer fits it must be requeued at the head (and served after a
    retirement), never over-allocated."""
    eng = ServeEngine(CFG, params, EngineConfig(
        slots=2, max_seq=64, page_size=8, prefill_chunk=16,
        prefix_cache=True, num_blocks=13, policy="prefill"))
    rng = np.random.default_rng(6)
    # seed the cache with 4 evictable blocks (ctx=32 -> 4 full blocks)
    warm_req = Request(rid=0, prompt=rng.integers(2, CFG.vocab_size,
                                                  size=33),
                       max_new_tokens=3)
    eng.run([warm_req])
    assert eng.allocator.live_blocks == 4       # cache-held, evictable
    # two picks in one tick, each needing 7 of the 12 usable blocks: both
    # pass can_admit (8 free + 4 evictable), only one can actually land
    reqs = [Request(rid=1 + i,
                    prompt=rng.integers(2, CFG.vocab_size, size=50),
                    max_new_tokens=3)
            for i in range(2)]
    done = eng.run(reqs)
    assert len(done) == 2                       # both served, in sequence
    assert all(len(r.out_tokens) == 3 for r in reqs)
    ticks = {rs.rid: rs.admit_tick for rs in eng.scheduler.finished
             if rs.rid > 0}
    assert ticks[1] != ticks[2]                 # the requeued one waited
    # requeued retries must not inflate hit/miss accounting: one committed
    # match per admission (3 admissions with ctx > 0)
    assert eng.radix.hits + eng.radix.misses == 3


def test_chunked_prefill_interleaves_with_decode(params):
    """A long prompt prefills in budgeted chunks across many ticks; a short
    co-batched request must decode to completion in the meantime (TTFT
    protection), and the long request still matches the full forward."""
    eng = ServeEngine(CFG, params, EngineConfig(
        slots=2, max_seq=256, page_size=8, prefill_chunk=16,
        policy="prefill"))
    rng = np.random.default_rng(4)
    long_req = Request(rid=0, prompt=rng.integers(2, CFG.vocab_size,
                                                  size=200),
                       max_new_tokens=2)
    short_req = Request(rid=1, prompt=rng.integers(2, CFG.vocab_size,
                                                   size=5),
                        max_new_tokens=2)
    eng.run([long_req, short_req])
    recs = {rs.rid: rs for rs in eng.scheduler.finished}
    assert recs[0].admit_tick == 0 and recs[1].admit_tick == 0
    # both admitted together, but the short request runs to *completion*
    # while the long prompt is still working through its 13 chunk grants
    assert [rs.rid for rs in eng.scheduler.finished] == [1, 0]
    assert recs[1].finish_time < recs[0].first_token_time
    assert len(long_req.out_tokens) == 2 and len(short_req.out_tokens) == 2


def test_prefix_cache_no_recompiles_after_warmup(params):
    """Hits, misses, COW copies, and evictions all reuse warmed traces."""
    eng = ServeEngine(CFG, params, ecfg(True, num_blocks=33))
    warm = eng.warmup()
    eng.run(shared_prefix_requests(6, seed=21))
    eng.run(shared_prefix_requests(6, seed=22, prefix_len=40))
    assert eng.compile_count() == warm


def test_prefix_cache_metrics_exposed(params):
    eng = ServeEngine(CFG, params, ecfg(True))
    eng.run(shared_prefix_requests(6))
    m = eng.metrics()
    assert m["prefix_cache"] is True
    assert m["cached_prefix_tokens"] > 0
    assert 0.0 < m["prefix_hit_rate"] < 1.0
    assert m["evictions"] == 0
    assert m["prefix_cache_hits"] > 0
    assert m["cached_prefix_tokens_per_request"] > 0
    off = ServeEngine(CFG, params, ecfg(False))
    off.run(shared_prefix_requests(6))
    mo = off.metrics()
    assert mo["prefix_hit_rate"] == 0.0 and mo["cached_prefix_tokens"] == 0


def test_prefix_cache_requires_paged(params):
    with pytest.raises(ValueError, match="paged"):
        ServeEngine(CFG, params, EngineConfig(slots=1, max_seq=64,
                                              paged=False, prefix_cache=True))
    with pytest.raises(ValueError, match="prefill_chunk"):
        ServeEngine(CFG, params, EngineConfig(slots=1, max_seq=64,
                                              page_size=16, prefill_chunk=24))
    with pytest.raises(ValueError, match="budget"):
        ServeEngine(CFG, params, EngineConfig(slots=1, max_seq=64,
                                              prefill_chunk=32,
                                              prefill_token_budget=16))


# ---------------------------------------------------------------------------
# GRAU modes: quantized streams stay bit-identical across reuse
# ---------------------------------------------------------------------------

def test_cache_on_off_bit_identical_grau_activation():
    """cfg.grau (QAT surrogate activations): integer activation math makes
    the on/off comparison exact by construction — and it must stay exact."""
    cfg = CFG.replace(grau=GRAUConfig())
    params, _ = lm.init_lm(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    out = {}
    for on in (False, True):
        eng = ServeEngine(cfg, params, ecfg(on))
        reqs = shared_prefix_requests(6, seed=31)
        eng.run(reqs)
        out[on] = {r.rid: tuple(r.out_tokens) for r in reqs}
    assert out[True] == out[False]


def test_cache_on_off_bit_identical_attn_grau_epilogue(params):
    """The fused GRAU attention-output epilogue runs in both the decode and
    the chunk-prefill attention; cached blocks must reproduce its quantized
    stream exactly."""
    g = build_lm_grau("identity", segments=6, num_exponents=8, mode="apot",
                      out_bits=8)
    out = {}
    for on in (False, True):
        eng = ServeEngine(CFG, params, ecfg(on, attn_grau=g))
        reqs = shared_prefix_requests(6, seed=41)
        eng.run(reqs)
        out[on] = {r.rid: tuple(r.out_tokens) for r in reqs}
    assert out[True] == out[False]


# ---------------------------------------------------------------------------
# Device-mesh matrix: reuse is placement-invariant
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("data,model", [(1, 2), (2, 2), (4, 1)])
def test_prefix_cache_under_mesh_matches_single_device(data, model, params):
    if jax.device_count() < data * model:
        pytest.skip(f"needs {data * model} devices")
    mesh = make_serve_mesh(data, model)
    cfg_on = ecfg(True, max_seq=64)
    base = ServeEngine(CFG, params, cfg_on)
    reqs = shared_prefix_requests(6, prefix_len=40, seed=51)
    base.run(reqs)
    base_toks = {r.rid: tuple(r.out_tokens) for r in reqs}

    sharded = ServeEngine(CFG, params, cfg_on, mesh=mesh)
    reqs2 = shared_prefix_requests(6, prefix_len=40, seed=51)
    sharded.run(reqs2)
    assert {r.rid: tuple(r.out_tokens) for r in reqs2} == base_toks
    # identical admissions => identical allocator state and hit accounting
    assert sharded.metrics()["cached_prefix_tokens"] == \
        base.metrics()["cached_prefix_tokens"]
    assert sharded.allocator.free_blocks == base.allocator.free_blocks

    # and under the mesh, reuse is still invisible vs cache-off
    off = ServeEngine(CFG, params, ecfg(False, max_seq=64), mesh=mesh)
    reqs3 = shared_prefix_requests(6, prefix_len=40, seed=51)
    off.run(reqs3)
    assert {r.rid: tuple(r.out_tokens) for r in reqs3} == base_toks
