"""Quantized paged KV cache under one PrecisionPolicy.

What must hold at kv_bits < 16 (and is tested here): pool construction
follows the policy per layer with eager packing validation; the decode write
path sets/bumps per-block power-of-two scale exponents deterministically;
the Pallas kernel, the gather fallback, and the jnp oracle read bit-identical
dequantized values (differential tests on fragmented tables, decode and
multi-query prefill modes); copy-on-write block copies carry scale metadata;
the serving engine keeps every existing invariant — kernel==gather token
streams, cache-on/off bit-identity, zero recompiles after warmup — at 8 and
4 bits, composed with the GRAU attention epilogue and under a device mesh;
and the packed pools actually shrink the gathered bytes per decode step.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.archs import get_config
from repro.core.hwcost import kv_cache_cost
from repro.launch.mesh import make_serve_mesh
from repro.models import lm
from repro.nn import attention as attn_lib
from repro.nn.attention import PagedKVCache, PagedState, QuantPagedKVCache
from repro.nn.common import build_lm_grau
from repro.kernels.ref import paged_attention_ref, paged_prefill_ref
from repro.quant import kv as kvq
from repro.quant.policy import PrecisionPolicy, kv_policy
from repro.serve import kv_cache as kvc
from repro.serve.engine import EngineConfig, Request, ServeEngine

BS = 8  # block size under test


@pytest.fixture(scope="module")
def tiny_lm():
    cfg = get_config("llama3.2-3b", smoke=True)
    params, _ = lm.init_lm(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    return cfg, params


def _serve(engine, cfg, *, n=5, max_new=6, seed=0):
    r = np.random.default_rng(seed)
    reqs = [Request(rid=i, prompt=r.integers(2, cfg.vocab_size,
                                             size=int(r.integers(3, 12))),
                    max_new_tokens=max_new) for i in range(n)]
    engine.run(reqs)
    return {q.rid: q.out_tokens for q in reqs}


def _quant_pool(rng, *, nb, kvh, hd, bits):
    hdp = kvq.packed_head_dim(hd, bits)
    return QuantPagedKVCache(
        k=jnp.zeros((nb, BS, kvh, hdp), jnp.int8),
        v=jnp.zeros((nb, BS, kvh, hdp), jnp.int8),
        k_exp=jnp.full((nb, kvh), kvq.EXP_EMPTY, jnp.int8),
        v_exp=jnp.full((nb, kvh), kvq.EXP_EMPTY, jnp.int8), bits=bits)


def _fragmented_case(rng, *, slots, kvh, hd, nblocks, nb, lengths, bits):
    """Pool filled through the real chunk write path over a shuffled
    (fragmented) block table, so every read path sees production layouts."""
    cache = _quant_pool(rng, nb=nb, kvh=kvh, hd=hd, bits=bits)
    free = list(range(1, nb))
    rng.shuffle(free)
    table = np.zeros((slots, nblocks), np.int32)
    for s, n in enumerate(lengths):
        for j in range(max(1, -(-int(n) // BS))):
            table[s, j] = free.pop()
    table = jnp.asarray(table)
    kn = jnp.asarray(rng.normal(size=(slots, nblocks * BS, kvh, hd)),
                     jnp.float32)
    vn = jnp.asarray(rng.normal(size=(slots, nblocks * BS, kvh, hd)),
                     jnp.float32)
    st0 = PagedState(table, jnp.zeros((slots,), jnp.int32))
    cache = attn_lib.paged_prefill_update(cache, kn, vn, st0)
    return cache, table


# ---------------------------------------------------------------------------
# Pool construction + validation (policy -> storage)
# ---------------------------------------------------------------------------

def test_policy_pool_construction(tiny_lm):
    cfg, _ = tiny_lm
    pools = kvc.init_paged_caches(cfg, 9, BS, policy=kv_policy(8))
    leaves = [leaf for grp in pools for leaf in grp]
    assert all(isinstance(c, QuantPagedKVCache) and c.bits == 8
               for c in leaves)
    c = leaves[0]
    assert c.k.dtype == jnp.int8 and c.k.shape[-1] == cfg.head_dim
    assert c.k_exp.shape == c.k.shape[:2] + (cfg.kv_heads_phys,)
    assert int(c.k_exp.min()) == kvq.EXP_EMPTY
    p4 = kvc.init_paged_caches(cfg, 9, BS, policy=kv_policy(4))
    assert all(leaf.k.shape[-1] == cfg.head_dim // 2
               for grp in p4 for leaf in grp)
    # no policy (or an all-16 one) keeps today's float pools
    p16 = kvc.init_paged_caches(cfg, 9, BS, policy=kv_policy(16))
    assert all(isinstance(leaf, PagedKVCache) for grp in p16 for leaf in grp)


def test_mixed_per_layer_policy(tiny_lm):
    cfg, _ = tiny_lm
    pol = PrecisionPolicy(kv_rules=((r"group0\.l0", 8),), kv_default_bits=16)
    pools = kvc.init_paged_caches(cfg, 9, BS, policy=pol)
    assert isinstance(pools[0][0], QuantPagedKVCache)
    flat = [leaf for grp in pools for leaf in grp]
    assert any(isinstance(leaf, PagedKVCache) for leaf in flat[1:]) or \
        len(flat) == 1
    assert kvc.kv_bits_by_layer(cfg, pol)[0][0] == 8


def test_eager_packing_validation(tiny_lm):
    cfg, _ = tiny_lm
    odd = cfg.replace(head_dim=31)
    with pytest.raises(ValueError, match="head_dim=31 is odd"):
        kvc.init_paged_caches(odd, 9, BS, policy=kv_policy(4))
    with pytest.raises(ValueError, match="block_size"):
        kvc.validate_pool_packing(cfg, 0, 8)
    with pytest.raises(ValueError, match="kv_bits"):
        kvc.validate_pool_packing(cfg, BS, 3)
    # dense caches reject quantized-KV policies with a pointer at paged
    with pytest.raises(ValueError, match="paged"):
        lm.init_caches(cfg, 1, 32, policy=kv_policy(8))


def test_engine_precision_validation(tiny_lm):
    cfg, params = tiny_lm
    with pytest.raises(ValueError, match="not both"):
        ServeEngine(cfg, params,
                    EngineConfig(slots=1, max_seq=32, kv_bits=8,
                                 precision=kv_policy(8)))
    with pytest.raises(ValueError, match="paged backend"):
        ServeEngine(cfg, params,
                    EngineConfig(slots=1, max_seq=32, paged=False, kv_bits=8))


# ---------------------------------------------------------------------------
# Write path: exponent set/bump semantics
# ---------------------------------------------------------------------------

def test_decode_write_sets_then_bumps_exponent(rng):
    kvh, hd = 2, 8
    cache = _quant_pool(rng, nb=4, kvh=kvh, hd=hd, bits=8)
    table = jnp.asarray([[1, 2]], jnp.int32)
    small = jnp.asarray(rng.normal(size=(1, 1, kvh, hd)) * 0.01, jnp.float32)
    big = jnp.asarray(rng.normal(size=(1, 1, kvh, hd)) * 100.0, jnp.float32)

    cache = attn_lib.paged_update(cache, small, small,
                                  PagedState(table, jnp.asarray([0])))
    e0 = np.asarray(cache.k_exp[1], np.int32)
    assert (e0 > kvq.EXP_EMPTY).all()      # first write *sets* the scale
    kd, _ = attn_lib.paged_view(cache, PagedState(table, jnp.asarray([0])))
    np.testing.assert_allclose(np.asarray(kd[0, 0]), np.asarray(small[0, 0]),
                               atol=2.0 ** float(e0.max()) * 0.51)

    cache = attn_lib.paged_update(cache, big, big,
                                  PagedState(table, jnp.asarray([1])))
    e1 = np.asarray(cache.k_exp[1], np.int32)
    assert (e1 > e0).all()                 # larger magnitude bumps the scale
    kd, _ = attn_lib.paged_view(cache, PagedState(table, jnp.asarray([1])))
    step = 2.0 ** float(e1.max())
    # position 0 was requantized by shift onto the coarser grid: still
    # within one new-grid step of the original value
    np.testing.assert_allclose(np.asarray(kd[0, 0]), np.asarray(small[0, 0]),
                               atol=step)
    np.testing.assert_allclose(np.asarray(kd[0, 1]), np.asarray(big[0, 0]),
                               atol=step * 0.51)


def test_chunk_padding_does_not_coarsen_block_scale(rng):
    """A chunk's pad rows (positions >= ctx) must not pick the block's scale
    exponent: with PagedState.ctx set, huge garbage K/V past the prompt
    leaves the real tokens' quantization grid untouched."""
    kvh, hd = 2, 8
    real = jnp.asarray(rng.normal(size=(1, BS, kvh, hd)) * 0.05, jnp.float32)
    pad = jnp.full((1, BS, kvh, hd), 1e4, jnp.float32)
    kn = jnp.concatenate([real, pad], axis=1)        # block 1 real, 2 pad
    table = jnp.asarray([[1, 2]], jnp.int32)
    ctx = jnp.asarray([BS], jnp.int32)               # only block 1 is real

    def written(with_ctx):
        cache = _quant_pool(rng, nb=4, kvh=kvh, hd=hd, bits=8)
        st = PagedState(table, jnp.zeros((1,), jnp.int32),
                        ctx if with_ctx else None)
        return attn_lib.paged_prefill_update(cache, kn, kn, st)

    masked, unmasked = written(True), written(False)
    # the fully-real block's exponent is identical either way...
    assert int(masked.k_exp[1, 0]) == int(unmasked.k_exp[1, 0])
    # ...and round-trips the real tokens at their own (fine) grid
    kd, _ = attn_lib.paged_view(masked, PagedState(table, jnp.asarray([BS])))
    step = 2.0 ** float(np.asarray(masked.k_exp[1], np.int32).max())
    np.testing.assert_allclose(np.asarray(kd[0, :BS]), np.asarray(real[0]),
                               atol=step * 0.51)
    # the partial-block scenario: one real row + huge padding in one block
    mixed = jnp.concatenate([real[:, :1], pad[:, 1:]], axis=1)
    cache = _quant_pool(rng, nb=4, kvh=kvh, hd=hd, bits=8)
    st = PagedState(table[:, :1], jnp.zeros((1,), jnp.int32),
                    jnp.asarray([1], jnp.int32))
    cache = attn_lib.paged_prefill_update(cache, mixed, mixed, st)
    kd, _ = attn_lib.paged_view(cache, PagedState(table[:, :1],
                                                  jnp.asarray([0])))
    fine_step = 2.0 ** float(np.asarray(cache.k_exp[1], np.int32).max())
    assert fine_step < 1e-2                 # scale follows the real row
    np.testing.assert_allclose(np.asarray(kd[0, 0]), np.asarray(real[0, 0]),
                               atol=fine_step * 0.51)


def test_copy_pool_block_carries_scale_metadata(rng):
    cache = _quant_pool(rng, nb=4, kvh=2, hd=8, bits=8)
    cache = dataclasses.replace(
        cache,
        k=cache.k.at[1].set(jnp.asarray(
            rng.integers(-127, 128, size=cache.k.shape[1:]), jnp.int8)),
        k_exp=cache.k_exp.at[1].set(5))
    pools = ((dataclasses.replace(
        cache, k=cache.k[None], v=cache.v[None], k_exp=cache.k_exp[None],
        v_exp=cache.v_exp[None]),),)    # stacked (repeats=1) layout
    out = kvc.copy_pool_block(pools, jnp.int32(1), jnp.int32(3))[0][0]
    np.testing.assert_array_equal(np.asarray(out.k[0, 3]),
                                  np.asarray(cache.k[1]))
    assert int(out.k_exp[0, 3, 0]) == 5    # exponent moved with the payload
    assert out.bits == 8


# ---------------------------------------------------------------------------
# Differential: kernel vs gather vs oracle at every kv_bits
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", [8, 4])
def test_decode_kernel_matches_gather_and_ref(rng, bits):
    slots, kvh, hd, h = 3, 2, 16, 6
    lengths = np.asarray([20, 9, 24], np.int32)
    cache, table = _fragmented_case(rng, slots=slots, kvh=kvh, hd=hd,
                                    nblocks=3, nb=12, lengths=lengths,
                                    bits=bits)
    q = jnp.asarray(rng.normal(size=(slots, 1, h, hd)), jnp.float32)
    st = PagedState(table, jnp.asarray(lengths - 1))
    got_k = attn_lib.paged_decode_attention(q, cache, st, impl="kernel")
    got_g = attn_lib.paged_decode_attention(q, cache, st, impl="gather")
    want = paged_attention_ref(q[:, 0], cache.k, cache.v, table,
                               jnp.asarray(lengths), k_exp=cache.k_exp,
                               v_exp=cache.v_exp, kv_bits=bits)
    np.testing.assert_allclose(np.asarray(got_k[:, 0]), np.asarray(want),
                               rtol=3e-5, atol=3e-5)
    np.testing.assert_allclose(np.asarray(got_g[:, 0]), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("bits", [8, 4])
def test_prefill_kernel_matches_gather_and_ref(rng, bits):
    slots, kvh, hd, h, C = 3, 2, 16, 6, 8
    cache, table = _fragmented_case(rng, slots=slots, kvh=kvh, hd=hd,
                                    nblocks=3, nb=12,
                                    lengths=[24, 24, 24], bits=bits)
    q = jnp.asarray(rng.normal(size=(slots, C, h, hd)), jnp.float32)
    starts = jnp.asarray([8, 0, 16], jnp.int32)
    pst = PagedState(table, starts)
    got_k = attn_lib.paged_prefill_attention(q, cache, pst, impl="kernel")
    got_g = attn_lib.paged_prefill_attention(q, cache, pst, impl="gather")
    want = paged_prefill_ref(q, cache.k, cache.v, table, starts,
                             k_exp=cache.k_exp, v_exp=cache.v_exp,
                             kv_bits=bits)
    np.testing.assert_allclose(np.asarray(got_k), np.asarray(want),
                               rtol=3e-5, atol=3e-5)
    np.testing.assert_allclose(np.asarray(got_g), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
# Engine end-to-end at kv_bits < 16
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", [8, 4])
def test_engine_kernel_matches_gather_quant(tiny_lm, bits):
    cfg, params = tiny_lm
    out = {}
    for impl in ("gather", "kernel"):
        engine = ServeEngine(cfg, params,
                             EngineConfig(slots=2, max_seq=64, page_size=BS,
                                          paged_impl=impl, kv_bits=bits))
        warm = engine.warmup()
        out[impl] = _serve(engine, cfg)
        assert engine.compile_count() == warm   # quant path is static too
    assert out["kernel"] == out["gather"]


@pytest.mark.parametrize("bits", [8, 4])
def test_engine_cache_on_off_bit_identical_quant(tiny_lm, bits):
    """Prefix reuse stays value-invisible with quantized pools: full-block
    reuse shares payload + exponent (identical writes produced them), and
    partial-block COW is disabled (a donor block's shared exponent would
    leak its suffix into the reused prefix)."""
    cfg, params = tiny_lm
    r = np.random.default_rng(7)
    pre = r.integers(2, cfg.vocab_size, size=40)
    reqs = lambda: [Request(rid=i, prompt=np.concatenate(
        [pre, r2.integers(2, cfg.vocab_size, size=int(r2.integers(2, 9)))]),
        max_new_tokens=4)
        for i, r2 in ((i, np.random.default_rng(100 + i)) for i in range(6))]
    toks = {}
    for on in (False, True):
        engine = ServeEngine(cfg, params,
                             EngineConfig(slots=2, max_seq=128, page_size=BS,
                                          prefill_chunk=16, prefix_cache=on,
                                          kv_bits=bits))
        warm = engine.warmup()
        rs = reqs()
        engine.run(rs)
        assert engine.compile_count() == warm
        toks[on] = {q.rid: q.out_tokens for q in rs}
        if on:
            assert engine.metrics()["prefix_hit_rate"] > 0
    assert toks[True] == toks[False]


def test_engine_quant_grau_epilogue_composes(tiny_lm):
    """KV quantization (storage) composes with the GRAU attention-output
    epilogue (compute): both impls still agree token-for-token."""
    cfg, params = tiny_lm
    g = build_lm_grau("identity", segments=6, num_exponents=8, mode="apot",
                      out_bits=8)
    out = {}
    for impl in ("gather", "kernel"):
        engine = ServeEngine(cfg, params,
                             EngineConfig(slots=2, max_seq=64, page_size=BS,
                                          paged_impl=impl, attn_grau=g,
                                          kv_bits=8))
        engine.warmup()
        out[impl] = _serve(engine, cfg)
    assert out["kernel"] == out["gather"]


def test_engine_quant_under_mesh(tiny_lm):
    """Quantized pools place under a (data, model) mesh — scale planes shard
    alongside payloads — and serve the same tokens as the unsharded engine."""
    cfg, params = tiny_lm
    out = {}
    for mesh in (None, make_serve_mesh(1, 2)):
        engine = ServeEngine(cfg, params,
                             EngineConfig(slots=2, max_seq=64, page_size=BS,
                                          kv_bits=8),
                             mesh=mesh)
        engine.warmup()
        out[mesh is None] = _serve(engine, cfg)
    assert out[True] == out[False]


def test_engine_gather_bytes_shrink(tiny_lm):
    """The acceptance gate, engine-level: int8 pools cut the per-step
    gathered bytes >= 1.8x vs 16-bit pools at the identical decode bucket
    (int4 cuts further), per the trip-count-aware HLO accounting."""
    cfg, params = tiny_lm
    gb = {}
    for bits in (16, 8, 4):
        engine = ServeEngine(cfg, params,
                             EngineConfig(slots=2, max_seq=64, page_size=BS,
                                          kv_bits=bits if bits != 16
                                          else None))
        gb[bits] = engine.decode_cost(
            engine.decode_buckets[-1])["gather_bytes"]
    assert gb[16] / gb[8] >= 1.8
    assert gb[16] / gb[4] > gb[16] / gb[8]


def test_engine_metrics_report_kv_bits(tiny_lm):
    cfg, params = tiny_lm
    engine = ServeEngine(cfg, params,
                         EngineConfig(slots=1, max_seq=32, page_size=BS,
                                      kv_bits=4))
    m = engine.metrics()
    assert m["kv_bits"] == 4 and m["kv_quantized"] is True


# ---------------------------------------------------------------------------
# hwcost: KV memory accounting
# ---------------------------------------------------------------------------

def test_kv_cache_cost_model():
    base = dict(num_layers=4, kv_heads=2, head_dim=32, block_size=16,
                slots=4, max_seq=128)
    r16 = kv_cache_cost(kv_bits=16, **base)
    r8 = kv_cache_cost(kv_bits=8, **base)
    r4 = kv_cache_cost(kv_bits=4, **base)
    assert r16.payload_bytes_per_token_layer == 2 * 2 * 32 * 2   # K+V bf16
    assert r16.scale_bytes_per_token_layer == 0.0
    assert r8.scale_bytes_per_token_layer == 2 * 2 / 16
    # payload halves each step down; scale overhead is amortized tiny
    assert r8.bytes_per_slot < r16.bytes_per_slot / 1.9
    assert r4.bytes_per_slot < r8.bytes_per_slot / 1.9
    assert r4.pool_bytes < r16.pool_bytes / 3.8
    # gather bytes follow live context, not capacity
    short = kv_cache_cost(kv_bits=8, ctx=16, **base)
    assert short.gather_bytes_per_step < r8.gather_bytes_per_step / 7
    with pytest.raises(ValueError, match="kv_bits"):
        kv_cache_cost(kv_bits=5, **base)
