"""Differential tests: every GRAU implementation agrees bit-exactly.

Three implementations of the paper's datapath exist — the Pallas kernel
(kernels/grau.py, run in interpret mode on CPU), the jnp oracle
(core.grau.grau_apply_int) and the numpy int64 host reference
(core.grau.grau_reference_int) — plus the fused MXU epilogue
(kernels/matmul_grau.py) against its unfused GEMM->GRAU oracle. Specs here
are *randomized register files* (random breakpoints, enc rows, signs,
biases, pre-shift sign, output precision), not fitted ones, so agreement
can't lean on any structure the fitter produces.

Inputs are bounded to |x| <= 2**20 with <= 8 exponent stages: the kernel and
jnp oracle accumulate in int32, the host reference in int64, and the
contract is only bit-exactness on ranges the 32-bit datapath represents
(8 * (2**20 << 2) < 2**31), matching the hardware's fixed accumulator width.

Property-based variants run when hypothesis is installed (CI does); the
seeded sweeps below always run, so this file never goes dark locally.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.grau import grau_apply_int, grau_reference_int
from repro.kernels import ops
from repro.kernels.ref import matmul_grau_ref
from repro.pwlf.spec import make_spec

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

X_BOUND = 1 << 20


def random_spec(rng: np.random.Generator):
    """A structurally valid, otherwise unconstrained GRAU register file."""
    segments = int(rng.integers(1, 9))
    num_exponents = int(rng.integers(1, 9))
    out_bits = int(rng.choice([2, 4, 8]))
    out_signed = bool(rng.integers(0, 2)) or out_bits == 2  # 2-bit unsigned
    # is fine too, but keep at least some negative-capable range in play
    bps = np.sort(rng.choice(
        np.arange(-X_BOUND, X_BOUND), size=segments - 1, replace=False)
    ) if segments > 1 else np.empty((0,), np.int64)
    return make_spec(
        breakpoints=bps,
        enc=rng.integers(0, 2, size=(segments, num_exponents)),
        sign=rng.choice([-1, 1], size=segments),
        bias=rng.integers(-100, 101, size=segments),
        pre_shift=int(rng.integers(-2, 9)),   # both shift directions
        num_exponents=num_exponents,
        out_bits=out_bits,
        out_signed=out_signed,
    )


def _assert_trio_agrees(x: np.ndarray, spec) -> None:
    xj = jnp.asarray(x, jnp.int32)
    kernel = np.asarray(ops.grau(xj, spec, interpret=True), np.int64)
    oracle = np.asarray(grau_apply_int(xj, spec), np.int64)
    host = grau_reference_int(x, spec)
    np.testing.assert_array_equal(kernel, oracle)
    np.testing.assert_array_equal(kernel, host)


@pytest.mark.parametrize("case", range(20))
def test_grau_trio_bit_exact_seeded(case):
    rng = np.random.default_rng(1000 + case)
    spec = random_spec(rng)
    shape = tuple(rng.integers(1, 130, size=int(rng.integers(1, 4))))
    x = rng.integers(-X_BOUND, X_BOUND, size=shape)
    _assert_trio_agrees(x, spec)


def test_grau_trio_bit_exact_at_breakpoints():
    """Comparator edges (x == bp, bp +/- 1) are where an off-by-one in the
    strict/non-strict comparison would hide; probe them directly."""
    rng = np.random.default_rng(7)
    for _ in range(10):
        spec = random_spec(rng)
        bps = np.asarray(spec.breakpoints, np.int64)
        real = bps[bps < np.iinfo(np.int32).max]          # skip pad entries
        probes = np.concatenate([real - 1, real, real + 1,
                                 np.array([-X_BOUND, 0, X_BOUND - 1])])
        _assert_trio_agrees(np.clip(probes, -X_BOUND, X_BOUND - 1), spec)


@pytest.mark.parametrize("case", range(10))
def test_matmul_grau_fused_vs_unfused_seeded(case):
    rng = np.random.default_rng(2000 + case)
    spec = random_spec(rng)
    m, k, n = (int(rng.integers(1, 97)) for _ in range(3))
    x = jnp.asarray(rng.integers(-128, 128, size=(m, k)), jnp.int8)
    w = jnp.asarray(rng.integers(-128, 128, size=(k, n)), jnp.int8)
    got = ops.matmul_grau(x, w, spec, tiles=(64, 64, 64), interpret=True)
    want = matmul_grau_ref(x, w, spec)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


if HAVE_HYPOTHESIS:

    @settings(max_examples=40, deadline=None)
    @given(seed=st.integers(0, 2**32 - 1),
           rows=st.integers(1, 80), cols=st.integers(1, 200))
    def test_grau_trio_bit_exact_hypothesis(seed, rows, cols):
        rng = np.random.default_rng(seed)
        spec = random_spec(rng)
        x = rng.integers(-X_BOUND, X_BOUND, size=(rows, cols))
        _assert_trio_agrees(x, spec)

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 2**32 - 1), m=st.integers(1, 70),
           k=st.integers(1, 70), n=st.integers(1, 70))
    def test_matmul_grau_fused_vs_unfused_hypothesis(seed, m, k, n):
        rng = np.random.default_rng(seed)
        spec = random_spec(rng)
        x = jnp.asarray(rng.integers(-128, 128, size=(m, k)), jnp.int8)
        w = jnp.asarray(rng.integers(-128, 128, size=(k, n)), jnp.int8)
        got = ops.matmul_grau(x, w, spec, tiles=(64, 64, 64), interpret=True)
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(matmul_grau_ref(x, w, spec)))
